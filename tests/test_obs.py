"""repro.obs: tracer semantics, metrics math, export/report, wire compat.

Covers the observability tentpole's contracts:

  - Tracer: disabled no-op path, span nesting/containment/ordering,
    sampling inheritance, bounded buffer, ingest with clock shift.
  - Metrics: Counter/Histogram math (empty window, single sample,
    window wraparound), registry reads, JSONL dump, scoped reset.
  - LatencyTracker keeps its historical snapshot shape on top of
    Histogram; EngineStats per-query counters aggregate across
    shards/hosts through the wire codec and the coordinator's fold.
  - Chrome export loads back validated; the report CLI enforces its
    host/stage floors with documented exit codes.
  - AMRP frames without the optional ``trace`` meta still parse
    (backward compatibility), and frames with it round-trip.
  - The deprecated counter surfaces (ops.LAUNCH_COUNTS,
    probing_cache_stats) warn once per read and mirror the registry.
"""

import json
import socket
import threading
import time
import warnings

import numpy as np
import pytest

from repro.obs import trace as obs_trace
from repro.obs.export import (
    chrome_trace_doc,
    load_chrome_trace,
    write_chrome_trace,
    write_metrics_jsonl,
)
from repro.obs.metrics import Counter, Histogram, MetricsRegistry
from repro.obs.report import main as report_main, summarize
from repro.obs.trace import NOOP_SPAN, Tracer


# ------------------------------------------------------------------ tracer
def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    assert tr.span("anything") is NOOP_SPAN
    with tr.span("anything", cat="x", foo=1):
        pass
    tr.record("manual", 0.0, 1.0)
    assert len(tr) == 0


def test_module_default_tracer_disabled():
    assert obs_trace.current().enabled is False


def test_set_tracer_returns_previous():
    live = Tracer(enabled=True)
    prev = obs_trace.set_tracer(live)
    try:
        assert obs_trace.current() is live
    finally:
        assert obs_trace.set_tracer(prev) is live
    assert obs_trace.current() is prev


def test_span_records_fields():
    tr = Tracer(enabled=True, host="h", trace_id="tid123")
    with tr.span("work", cat="test", n=3):
        time.sleep(0.001)
    (s,) = tr.snapshot()
    assert s["name"] == "work"
    assert s["cat"] == "test"
    assert s["host"] == "h"
    assert s["trace"] == "tid123"
    assert s["dur"] >= 1000.0          # >= 1 ms in µs
    assert s["args"]["n"] == 3
    assert isinstance(s["pid"], int) and isinstance(s["tid"], int)
    # spans are JSON-safe by construction (they cross pipes and frames)
    json.dumps(s)


def test_span_nesting_containment_and_order():
    tr = Tracer(enabled=True)
    with tr.span("outer"):
        with tr.span("inner"):
            time.sleep(0.001)
        with tr.span("inner2"):
            pass
    spans = {s["name"]: s for s in tr.snapshot()}
    assert set(spans) == {"outer", "inner", "inner2"}
    out, inn, inn2 = spans["outer"], spans["inner"], spans["inner2"]
    # interval containment: children nest inside the parent
    for child in (inn, inn2):
        assert child["ts"] >= out["ts"]
        assert child["ts"] + child["dur"] <= out["ts"] + out["dur"]
    # sibling ordering on the timeline
    assert inn["ts"] + inn["dur"] <= inn2["ts"]
    # depth args record the nesting level
    assert out["args"]["depth"] == 0
    assert inn["args"]["depth"] == 1
    # append-on-exit: children land in the buffer before their parent
    names = [s["name"] for s in tr.snapshot()]
    assert names.index("inner") < names.index("outer")


def test_span_stack_balanced_on_exception():
    tr = Tracer(enabled=True)
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise RuntimeError("boom")
    # both spans still recorded, and the stack is clean for the next one
    assert {s["name"] for s in tr.snapshot()} == {"outer", "inner"}
    with tr.span("after"):
        pass
    assert tr.snapshot()[-1]["args"]["depth"] == 0


def test_sampling_zero_drops_subtree_but_not_record():
    tr = Tracer(enabled=True, sample=0.0)
    for _ in range(10):
        with tr.span("top"):
            with tr.span("child"):   # inherits the sampled-out decision
                pass
    assert len(tr) == 0
    tr.record("manual", 0.0, 1.0)    # record() bypasses sampling
    assert len(tr) == 1


def test_sampling_decision_inherited_whole():
    # sample=0.5: every recorded child must come with its parent —
    # a subtree is kept or dropped as a unit, never split
    tr = Tracer(enabled=True, sample=0.5)
    tr._rng.seed(7)
    for i in range(50):
        with tr.span("top", i=i):
            with tr.span("child", i=i):
                pass
    spans = tr.snapshot()
    tops = {s["args"]["i"] for s in spans if s["name"] == "top"}
    children = {s["args"]["i"] for s in spans if s["name"] == "child"}
    assert tops == children
    assert 0 < len(tops) < 50


def test_max_spans_bounds_buffer():
    tr = Tracer(enabled=True, max_spans=3)
    for i in range(5):
        tr.record(f"s{i}", 0.0, 1.0)
    assert len(tr) == 3
    assert tr.dropped == 2


def test_ingest_shifts_and_retags():
    tr = Tracer(enabled=True, trace_id="parent")
    child = [{"name": "w", "cat": "x", "ts": 1000.0, "dur": 5.0,
              "pid": 9, "tid": 1, "host": "worker", "trace": "other"}]
    tr.ingest(child, shift_us=250.0)
    (s,) = tr.snapshot()
    assert s["ts"] == 750.0            # shifted onto the parent clock
    assert s["trace"] == "parent"      # merged under one trace id
    assert s["host"] == "worker"
    assert child[0]["ts"] == 1000.0    # caller's list untouched


def test_ingest_defaults_missing_host():
    tr = Tracer(enabled=True)
    tr.ingest([{"name": "w", "ts": 0.0, "dur": 1.0}], host="h3")
    assert tr.snapshot()[0]["host"] == "h3"


def test_drain_empties_buffer():
    tr = Tracer(enabled=True)
    tr.record("a", 0.0, 1.0)
    assert [s["name"] for s in tr.drain()] == ["a"]
    assert len(tr) == 0


def test_spans_from_threads_keep_independent_stacks():
    tr = Tracer(enabled=True)
    errors = []

    def work(tag):
        try:
            for _ in range(50):
                with tr.span(f"outer-{tag}"):
                    with tr.span(f"inner-{tag}"):
                        pass
        except Exception as e:          # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=work, args=(t,)) for t in "ab"]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    spans = tr.snapshot()
    assert len(spans) == 200
    # every inner span is depth 1: the two threads never saw each
    # other's stack
    for s in spans:
        want = 1 if s["name"].startswith("inner") else 0
        assert s["args"]["depth"] == want


# ----------------------------------------------------------------- metrics
def test_counter_add_set():
    c = Counter()
    assert c.value == 0
    c.add()
    c.add(4)
    assert c.value == 5
    c.set(2)
    assert c.value == 2


def test_histogram_empty_window():
    assert Histogram().snapshot() == {}


def test_histogram_single_sample():
    h = Histogram()
    h.record(7.0)
    snap = h.snapshot()
    assert snap["p50"] == snap["p99"] == snap["mean"] == snap["max"] == 7.0
    assert snap["count"] == 1


def test_histogram_window_wraparound():
    h = Histogram(window=4)
    for v in range(10):                 # 0..9; window keeps 6,7,8,9
        h.record(float(v))
    snap = h.snapshot()
    assert snap["count"] == 10          # lifetime count survives the trim
    assert snap["max"] == 9.0
    assert snap["mean"] == pytest.approx((6 + 7 + 8 + 9) / 4)
    assert snap["p50"] >= 6.0           # percentiles score the window only


def test_histogram_batch_count():
    h = Histogram(window=8)
    h.record(3.0, count=5)
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["p50"] == 3.0


def test_registry_reads_and_reset():
    reg = MetricsRegistry()
    reg.counter("a.x").add(2)
    reg.counter("a.y").add(1)
    reg.counter("b.z").add(9)
    reg.histogram("a.h").record(1.5)
    assert reg.value("a.x") == 2
    assert reg.value("never.touched") == 0
    assert reg.values("a.") == {"a.x": 2, "a.y": 1}
    snap = reg.snapshot()
    assert snap["b.z"] == 9 and snap["a.h"]["count"] == 1
    reg.reset("a.")
    assert reg.value("a.x") == 0
    assert reg.value("b.z") == 9        # prefix scoped the reset
    assert "a.h" not in reg.snapshot()


def test_registry_dump_jsonl(tmp_path):
    reg = MetricsRegistry()
    reg.counter("launches.verify").add(3)
    reg.histogram("lat").record(2.0)
    path = tmp_path / "metrics.jsonl"
    reg.dump_jsonl(str(path))
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    by_name = {r["metric"]: r["value"] for r in rows}
    assert by_name["launches.verify"] == 3
    assert by_name["lat"]["count"] == 1
    write_metrics_jsonl(str(tmp_path / "global.jsonl"))   # global smoke


# --------------------------------------------- latency tracker / stats agg
def test_latency_tracker_empty_window():
    from repro.pipeline.stream import LatencyTracker

    assert LatencyTracker().snapshot() == {}


def test_latency_tracker_single_sample():
    from repro.pipeline.stream import LatencyTracker

    t = LatencyTracker()
    t.record(12.5)
    snap = t.snapshot()
    assert snap["p50"] == snap["p99"] == snap["mean"] == 12.5
    assert snap["count"] == 1.0


def test_latency_tracker_window_wraparound():
    from repro.pipeline.stream import LatencyTracker

    t = LatencyTracker(window=4)
    for v in range(10):
        t.record(float(v))
    snap = t.snapshot()
    assert snap["count"] == 10.0        # lifetime, like before
    assert snap["mean"] == pytest.approx((6 + 7 + 8 + 9) / 4)
    # np.percentile interpolates inside the window (historical shape)
    assert snap["p50"] == pytest.approx(7.5)
    assert 6.0 <= snap["p99"] <= 9.0


def test_latency_tracker_is_histogram():
    from repro.pipeline.stream import LatencyTracker

    assert issubclass(LatencyTracker, Histogram)


def test_engine_stats_aggregate_across_shards_and_hosts():
    """Per-query rows travel the wire codec and fold across hosts the
    way the coordinator merges them: ints sum, max_radius maxes, bools
    or."""
    from repro.cluster.coordinator import _fold_counters
    from repro.cluster.worker import stats_from_wire, stats_to_wire
    from repro.core.amih import AMIHStats
    from repro.core.engine import EngineStats

    host_stats = []
    for h, (probes, radius, fell) in enumerate(
        [(10, 2, False), (7, 5, True)]
    ):
        st = EngineStats(
            backend="sharded_amih", queries=1,
            per_query=[AMIHStats(probes=probes, verified=3,
                                 max_radius=radius,
                                 fell_back_to_scan=fell)],
            shards=2,
            per_shard=[{"shard": h, "launches": 1}],
        )
        host_stats.append(stats_from_wire(stats_to_wire(st)))

    agg = AMIHStats()
    for st in host_stats:
        assert isinstance(st.per_query[0], AMIHStats)   # codec keeps kind
        _fold_counters(agg, st.per_query[0])
    assert agg.probes == 17
    assert agg.verified == 6
    assert agg.max_radius == 5          # max across hosts, not sum
    assert agg.fell_back_to_scan is True
    # EngineStats.aggregate applies the same rules across a batch
    combined = EngineStats(backend="x", queries=2,
                           per_query=[st.per_query[0]
                                      for st in host_stats])
    totals = combined.aggregate()
    assert totals["probes"] == 17 and totals["max_radius"] == 5


# ---------------------------------------------------------- export/report
def _spans_two_hosts():
    return [
        {"name": "engine.knn_batch", "cat": "engine", "ts": 0.0,
         "dur": 100.0, "pid": 1, "tid": 1, "host": "coordinator",
         "trace": "t1"},
        {"name": "amih.probe", "cat": "amih", "ts": 10.0, "dur": 20.0,
         "pid": 2, "tid": 1, "host": "host0", "trace": "t1"},
        {"name": "amih.verify", "cat": "amih", "ts": 30.0, "dur": 40.0,
         "pid": 2, "tid": 1, "host": "host0", "trace": "t1"},
    ]


def test_chrome_trace_doc_structure():
    doc = chrome_trace_doc(_spans_two_hosts(), trace_id="t1")
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {m["args"]["name"] for m in metas} == {"coordinator", "host0"}
    assert len(xs) == 3
    # one synthetic pid per host lane, trace id carried in args
    assert len({e["pid"] for e in xs}) == 2
    assert all(e["args"]["trace"] == "t1" for e in xs)
    assert doc["metadata"]["trace_id"] == "t1"


def test_write_load_chrome_trace_roundtrip(tmp_path):
    path = str(tmp_path / "trace.json")
    assert write_chrome_trace(_spans_two_hosts(), path) == 3
    doc = load_chrome_trace(path)
    assert len(doc["traceEvents"]) == 5   # 3 spans + 2 process_name


def test_load_chrome_trace_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"notTraceEvents": 1}')
    with pytest.raises(ValueError):
        load_chrome_trace(str(bad))
    worse = tmp_path / "worse.json"
    worse.write_text('{"traceEvents": [{"ph": "X", "name": "x"}]}')
    with pytest.raises(ValueError):       # X event without ts/dur
        load_chrome_trace(str(worse))


def test_report_summarize():
    doc = chrome_trace_doc(_spans_two_hosts())
    summary = summarize(doc)
    assert summary["hosts"] == ["coordinator", "host0"]
    assert summary["wall_ms"] == pytest.approx(0.1)   # 100 µs
    st = summary["stages"]["amih.probe"]
    assert st["count"] == 1 and st["total_ms"] == pytest.approx(0.02)
    assert st["hosts"] == ["host0"]


def test_report_cli_exit_codes(tmp_path, capsys):
    path = str(tmp_path / "trace.json")
    write_chrome_trace(_spans_two_hosts(), path)
    assert report_main([path, "--min-hosts", "2", "--min-stages", "3"]) == 0
    out = capsys.readouterr().out
    assert "engine.knn_batch" in out and "% wall" in out
    # unmet floors -> 1
    assert report_main([path, "--min-hosts", "3"]) == 1
    assert report_main([path, "--min-stages", "4"]) == 1
    # unreadable/invalid file -> 2
    bad = tmp_path / "bad.json"
    bad.write_text("not json")
    assert report_main([str(bad)]) == 2
    assert report_main([str(tmp_path / "missing.json")]) == 2


# ------------------------------------------------------- engine integration
def test_make_engine_tracer_spans_observed():
    from repro.core.engine import make_engine
    from repro.core.packing import pack_bits

    rng = np.random.default_rng(0)
    db = pack_bits(rng.integers(0, 2, (300, 64), dtype=np.uint8))
    qs = pack_bits(rng.integers(0, 2, (4, 64), dtype=np.uint8))
    base = make_engine("amih", db, 64)
    ref_ids, ref_sims, _ = base.knn_batch(qs, 5)

    tracer = Tracer(enabled=True)
    prev = obs_trace.current()
    try:
        eng = make_engine("amih", db, 64, tracer=tracer)
        assert eng.tracer is tracer
        ids, sims, _ = eng.knn_batch(qs, 5)
    finally:
        obs_trace.set_tracer(prev)
    # spans observe, never reorder: bit-identical to the untraced engine
    np.testing.assert_array_equal(ids, ref_ids)
    np.testing.assert_array_equal(sims, ref_sims)
    names = {s["name"] for s in tracer.snapshot()}
    assert "engine.knn_batch" in names
    assert {"amih.probe", "amih.emit"} <= names


# ------------------------------------------------------------ wire compat
def _frame_roundtrip(kind, meta, arrays=None):
    from repro.cluster.transport import recv_frame, send_frame

    a, b = socket.socketpair()
    try:
        send_frame(a, kind, meta, arrays)
        return recv_frame(b)
    finally:
        a.close()
        b.close()


def test_frames_without_trace_meta_still_parse():
    """Backward compatibility: the optional ``trace`` field is absent
    from old coordinators' search frames and old workers' results."""
    kind, meta, arrays = _frame_roundtrip(
        "search", {"req": 1, "k": 5},
        {"q": np.arange(4, dtype=np.uint64).reshape(2, 2),
         "floor": np.zeros(2)},
    )
    assert kind == "search"
    assert meta["req"] == 1 and "trace" not in meta
    assert arrays["q"].shape == (2, 2)


def test_frames_with_trace_meta_roundtrip():
    trace = {"id": "abc123", "host": "host1"}
    spans = [{"name": "amih.probe", "cat": "amih", "ts": 1.0, "dur": 2.0,
              "pid": 5, "tid": 6, "host": "host1", "trace": "abc123"}]
    kind, meta, _ = _frame_roundtrip(
        "search", {"req": 2, "k": 3, "trace": trace}, {"q": np.zeros(1)}
    )
    assert meta["trace"] == trace
    kind, meta, _ = _frame_roundtrip(
        "result", {"req": 2, "stats": {}, "spans": spans},
        {"ids": np.zeros(1, np.int64), "sims": np.zeros(1),
         "lens": np.ones(1, np.int64)},
    )
    assert meta["spans"] == spans
    kind, meta, _ = _frame_roundtrip("pong", {"seq": 7, "ts": 123.5})
    assert meta["ts"] == 123.5


# ------------------------------------------------------ deprecated aliases
def test_launch_counts_alias_warns_and_mirrors_registry():
    from repro.kernels import ops
    from repro.obs.metrics import REGISTRY

    with pytest.warns(DeprecationWarning, match="LAUNCH_COUNTS"):
        before = ops.LAUNCH_COUNTS["verify"]
    assert before == REGISTRY.value("launches.verify")
    assert set(ops.LAUNCH_COUNTS) == {
        "verify_grouped", "verify", "device_probe", "device_probe_scan",
    }
    assert len(ops.LAUNCH_COUNTS) == 4
    with pytest.warns(DeprecationWarning):
        with pytest.raises(KeyError):
            ops.LAUNCH_COUNTS["nonsense"]


def test_probing_cache_stats_warns_and_matches_internal():
    from repro.core import probing

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        internal = probing._cache_stats()        # new surface: no warning
    with pytest.warns(DeprecationWarning, match="probing_cache_stats"):
        legacy = probing.probing_cache_stats()
    assert legacy == internal
    assert {"probing_hits", "probing_misses"} <= set(legacy)
