"""The async pipelined serving subsystem (repro.pipeline): every
pipelined path — AMIH verify/probe overlap, shard-parallel probing under
the shared monotone bound (process and thread modes), and the streaming
serving loop — returns bit-identical results to its sequential
counterpart and to ``linear_scan_knn``; the shared-bound search never
returns worse than the exact k-th cosine; the StagedExecutor pipelines in
order; ``RetrievalService.submit`` is thread-safe and streaming serving
resolves tickets with latency counters."""

import multiprocessing
import time

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import linear_scan_knn, make_engine, pack_bits
from repro.core.linear_scan import sims_against_db
from repro.data import synthetic_binary_codes, synthetic_queries
from repro.pipeline import (
    SharedBound,
    Stage,
    StagedExecutor,
    Ticket,
    prime_ids,
    stream_search,
)

ALL_BACKENDS = (
    "linear_scan", "single_table", "amih", "sharded_scan", "sharded_amih"
)


def _force_pool(eng):
    """Zero the adaptive stand-down gates so small test fixtures (and
    this 2-core CI host) actually exercise the parallel pool."""
    eng.PARALLEL_MIN_SHARD_ROWS = 0
    eng.PARALLEL_MIN_CPUS = 0
    eng.PARALLEL_MIN_BATCH = 0
    return eng


@pytest.fixture(autouse=True)
def _at_least_two_cpus(monkeypatch):
    """The pool caps its worker count at ``cpu_count()``, so on a 1-CPU
    host it would (correctly) collapse to the inline path and the fork-
    lifecycle assertions below would never see a worker. Floor the count
    at 2 for this module so the fork machinery is exercised everywhere
    the suite runs."""
    if multiprocessing.cpu_count() < 2:
        monkeypatch.setattr(multiprocessing, "cpu_count", lambda: 2)


def _pipelined_engine(backend, db, p):
    """The backend's pipelined build (engines without an engine-level
    pipelined mode are served through the streaming loop instead)."""
    if backend == "amih":
        return make_engine("amih", db, p, overlap_verify=True)
    if backend == "sharded_amih":
        return _force_pool(make_engine(
            "sharded_amih", db, p, num_shards=4, probe_workers=4
        ))
    if backend == "sharded_scan":
        return make_engine("sharded_scan", db, p, num_shards=4)
    return make_engine(backend, db, p)


def _check_exact(ids, sims, qs, db, k_eff):
    """Exact vs the scan, as a multiset: sims rows are compared SORTED
    because AMIH emits in exact-rational tuple order, which can disagree
    with the scan's float64 sort by one ulp when two DISTINCT tuples'
    sims collide in float64 (pre-existing sequential behavior — the
    pipelined-vs-sequential checks elsewhere stay bitwise). Every
    returned id still carries its true sim, bit-exact."""
    B = qs.shape[0]
    assert ids.shape == (B, k_eff) and sims.shape == (B, k_eff)
    for i in range(B):
        _, sims_l = linear_scan_knn(qs[i], db, k_eff)
        np.testing.assert_array_equal(np.sort(sims[i])[::-1], sims_l)
        all_sims = sims_against_db(qs[i], db)
        np.testing.assert_array_equal(all_sims[ids[i]], sims[i])


# ------------------------------------------------- pipelined == sequential
@given(
    B=st.sampled_from([1, 8, 64]),
    n=st.integers(30, 300),
    k=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=6, deadline=None)
def test_pipelined_exact_all_backends(B, n, k, seed):
    """Every backend, served pipelined (engine-level pipelining where it
    exists, the streaming loop everywhere), stays bit-identical to
    linear_scan_knn — B in {1, 8, 64}, K > shard rows included via small
    n with 4 shards."""
    p = 64
    db_bits = synthetic_binary_codes(n, p, seed=seed)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=seed + 1))
    db = pack_bits(db_bits)
    k_eff = min(k, n)
    for backend in ALL_BACKENDS:
        eng = _pipelined_engine(backend, db, p)
        ids, sims, _ = eng.knn_batch(qs, k)
        _check_exact(ids, sims, qs, db, k_eff)
        # streamed serving over the same engine: same rows, in order
        step = max(1, B // 2)
        batches = [qs[lo : lo + step] for lo in range(0, B, step)]
        got = np.concatenate(
            [sr.sims for sr in stream_search(eng, batches, k)]
        )
        np.testing.assert_array_equal(got, sims)


def test_overlap_matches_sequential_amih_bit_identical():
    p, n, B, k = 64, 400, 16, 10
    db_bits = synthetic_binary_codes(n, p, seed=3)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=4))
    qs[2] = 0                                  # zero-norm query rides along
    db = pack_bits(db_bits)
    e_seq = make_engine("amih", db, p)
    e_ovl = make_engine("amih", db, p, overlap_verify=True)
    ids_s, sims_s, _ = e_seq.knn_batch(qs, k)
    ids_o, sims_o, _ = e_ovl.knn_batch(qs, k)
    np.testing.assert_array_equal(ids_s, ids_o)
    np.testing.assert_array_equal(sims_s, sims_o)
    assert np.all(sims_o[2] == 0.0)


def test_overlap_matches_sequential_pallas_verify():
    """Overlap composes with the device verify backend (the worker issues
    the non-blocking grouped launch)."""
    p, n, B, k = 96, 150, 6, 7
    db_bits = synthetic_binary_codes(n, p, seed=5)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=6))
    db = pack_bits(db_bits)
    e_seq = make_engine("amih", db, p, verify_backend="pallas")
    e_ovl = make_engine(
        "amih", db, p, verify_backend="pallas", overlap_verify=True
    )
    ids_s, sims_s, _ = e_seq.knn_batch(qs, k)
    ids_o, sims_o, _ = e_ovl.knn_batch(qs, k)
    np.testing.assert_array_equal(ids_s, ids_o)
    np.testing.assert_array_equal(sims_s, sims_o)


@pytest.mark.parametrize("mode", ["process", "thread"])
def test_shard_parallel_matches_sequential(mode):
    """Shared-bound parallel probing == sequential chain == linear scan,
    uneven N, both worker modes."""
    p, n, B, k, S = 64, 997, 16, 10, 8
    db_bits = synthetic_binary_codes(n, p, seed=7)
    qs = pack_bits(synthetic_queries(db_bits, B, seed=8))
    db = pack_bits(db_bits)
    e_seq = make_engine("sharded_amih", db, p, num_shards=S)
    e_par = _force_pool(make_engine(
        "sharded_amih", db, p, num_shards=S, probe_workers=S,
        probe_mode=mode,
    ))
    assert e_par._use_parallel(B)
    ids_s, sims_s, st_s = e_seq.knn_batch(qs, k)
    ids_p, sims_p, st_p = e_par.knn_batch(qs, k)
    np.testing.assert_array_equal(ids_s, ids_p)
    np.testing.assert_array_equal(sims_s, sims_p)
    _check_exact(ids_p, sims_p, qs, db, k)
    assert st_p.shards == S and len(st_p.per_shard) == S
    # per_shard rows cover the DB in shard-id order either way
    assert [d["shard"] for d in st_p.per_shard] == list(range(S))
    assert sum(d["rows"] for d in st_p.per_shard) == n
    # verify-launch deltas travel back from the workers (a forked
    # child's index counters never reach the parent's objects)
    assert sum(d["launches"] for d in st_p.per_shard) > 0


@pytest.mark.parametrize("mode", ["process", "thread"])
def test_persistent_pool_forks_once_per_engine(mode):
    """The ROADMAP's persistent probe pool: workers start on the first
    parallel call and every later call reuses them — fork count and
    worker PIDs stay flat across calls, results stay exact, and
    ``close()`` releases the workers (idempotently)."""
    p, n, B, k, S = 64, 900, 12, 8, 8
    db_bits = synthetic_binary_codes(n, p, seed=40)
    db = pack_bits(db_bits)
    eng = _force_pool(make_engine(
        "sharded_amih", db, p, num_shards=S, probe_workers=S,
        probe_mode=mode,
    ))
    assert eng._pool is None                   # no workers before first call
    qs1 = pack_bits(synthetic_queries(db_bits, B, seed=41))
    qs2 = pack_bits(synthetic_queries(db_bits, B, seed=42))
    ids1, sims1, _ = eng.knn_batch(qs1, k)
    pool = eng._pool
    assert pool is not None
    forks0, pids0 = pool.forks, pool.worker_pids()
    if mode == "process":
        assert forks0 == len(pool.groups) > 0
        assert len(pids0) == forks0
    else:
        assert forks0 == 0 and pids0 == []
    for qs in (qs2, qs1):                      # repeat calls, same workers
        ids, sims, _ = eng.knn_batch(qs, k)
        _check_exact(ids, sims, qs, db, k)
    _check_exact(ids1, sims1, qs1, db, k)
    assert eng._pool is pool
    assert pool.forks == forks0 and pool.worker_pids() == pids0
    eng.close()
    assert eng._pool is None
    eng.close()                                # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        pool.probe(qs1, k, None)


def test_persistent_pool_batch_size_changes_between_calls():
    """The per-call bounds segment is sized to the call's batch, so one
    pool serves B=1 and B=32 calls alike without re-forking."""
    p, n, k, S = 64, 700, 6, 8
    db_bits = synthetic_binary_codes(n, p, seed=43)
    db = pack_bits(db_bits)
    eng = _force_pool(make_engine(
        "sharded_amih", db, p, num_shards=S, probe_workers=S,
        probe_mode="process",
    ))
    forks = None
    for B in (1, 32, 4):
        qs = pack_bits(synthetic_queries(db_bits, B, seed=44 + B))
        ids, sims, _ = eng.knn_batch(qs, k)
        _check_exact(ids, sims, qs, db, min(k, n))
        forks = eng._pool.forks if forks is None else forks
        assert eng._pool.forks == forks
    eng.close()


def test_shard_parallel_k_exceeds_shard_rows():
    p, n, k, S = 64, 50, 40, 8                 # ~6 rows/shard, k=40
    db_bits = synthetic_binary_codes(n, p, seed=9)
    qs = pack_bits(synthetic_queries(db_bits, 4, seed=10))
    db = pack_bits(db_bits)
    eng = _force_pool(make_engine(
        "sharded_amih", db, p, num_shards=S, probe_workers=S
    ))
    ids, sims, _ = eng.knn_batch(qs, k)
    _check_exact(ids, sims, qs, db, k)
    ids, sims, _ = eng.knn_batch(qs, 99)       # k > n clamps too
    _check_exact(ids, sims, qs, db, n)


def test_parallel_floor_falls_back_to_sequential():
    """Adaptive stand-down: tiny shards, narrow batches, or a host
    without real cores run the sequential chain instead of the pool."""
    p, n = 64, 120
    db_bits = synthetic_binary_codes(n, p, seed=11)
    db = pack_bits(db_bits)
    eng = make_engine("sharded_amih", db, p, num_shards=4, probe_workers=4)
    assert not eng._use_parallel(32)       # 30 rows/shard < row floor
    _force_pool(eng)
    assert eng._use_parallel(32) and eng._use_parallel(1)
    eng.PARALLEL_MIN_BATCH = 8
    assert not eng._use_parallel(1)        # narrow batch: fork unamortized
    eng.PARALLEL_MIN_BATCH = 0
    eng.PARALLEL_MIN_CPUS = 10**6
    assert not eng._use_parallel(32)       # no real cores: pool loses


def test_shared_bound_never_worse_than_exact_kth():
    """Determinism/exactness of the shared bound: across many batches the
    k-th sim the parallel engine returns equals the exact k-th cosine
    (never below it — the monotone bound may only prune, not lose)."""
    p, n, k, S = 64, 1201, 7, 8
    db_bits = synthetic_binary_codes(n, p, seed=12)
    db = pack_bits(db_bits)
    eng = _force_pool(make_engine(
        "sharded_amih", db, p, num_shards=S, probe_workers=S
    ))
    for seed in range(3):
        qs = pack_bits(synthetic_queries(db_bits, 8, seed=20 + seed))
        _, sims, _ = eng.knn_batch(qs, k)
        for i in range(8):
            exact = np.sort(sims_against_db(qs[i], db))[::-1]
            assert sims[i, -1] == exact[k - 1]


def test_shared_bound_monotone_and_dedups():
    sb = SharedBound(2, 3)
    assert np.all(np.isinf(sb.bounds)) and np.all(sb.bounds < 0)
    ids = np.array([5, 9, 11], dtype=np.int64)
    sims = np.array([0.9, 0.8, 0.7])
    sb.offer(0, ids, sims)
    assert sb.bounds[0] == pytest.approx(0.7)
    # re-offering the same ids must NOT inflate the k-th
    sb.offer(0, ids, sims)
    assert sb.bounds[0] == pytest.approx(0.7)
    # better candidates raise it; worse ones never lower it
    sb.offer(0, np.array([2], dtype=np.int64), np.array([0.95]))
    assert sb.bounds[0] == pytest.approx(0.8)
    sb.offer(0, np.array([3], dtype=np.int64), np.array([0.1]))
    assert sb.bounds[0] == pytest.approx(0.8)
    assert np.isinf(sb.bounds[1]) and sb.bounds[1] < 0
    assert prime_ids(100, 3).size <= 100


def test_live_bound_reads_per_tuple_step():
    """knn_batch_bounded reads the bound array live (no defensive copy):
    raising it mid-search prunes the remaining tuple walk."""
    from repro.core import AMIHIndex, AMIHStats

    p, n, k = 64, 600, 5
    db_bits = synthetic_binary_codes(n, p, seed=13)
    db = pack_bits(db_bits)
    q = pack_bits(synthetic_queries(db_bits, 1, seed=14))
    index = AMIHIndex.build(db, p)
    free = [AMIHStats()]
    index.knn_batch_bounded(q, k, stop_below=np.array([-np.inf]),
                            stats=free)
    bounds = np.array([-np.inf])
    seen = []

    def on_done(qi, ids, sims):
        seen.append((qi, ids.copy(), sims.copy()))
        bounds[qi] = np.inf     # slam the live bound shut after k fills

    st = [AMIHStats()]
    res = index.knn_batch_bounded(
        q, k, stop_below=bounds, stats=st, on_done=on_done
    )
    assert seen and seen[0][0] == 0 and seen[0][2].size == k
    # the slammed bound stopped the walk no later than the free run
    assert st[0].tuples_processed <= free[0].tuples_processed
    np.testing.assert_array_equal(
        res[0][1], np.sort(sims_against_db(q[0], db))[::-1][:k]
    )


# --------------------------------------------------------- StagedExecutor
def test_staged_executor_orders_and_overlaps():
    order = []

    def slow_a(x):
        time.sleep(0.01)
        order.append(("a", x))
        return x + 1

    def slow_b(x):
        time.sleep(0.01)
        order.append(("b", x))
        return x * 2

    with StagedExecutor([Stage("a", slow_a), Stage("b", slow_b)]) as ex:
        out = list(ex.map(range(6)))
    assert out == [(i + 1) * 2 for i in range(6)]
    # overlap happened: some stage-a work ran before earlier items
    # finished stage b (strict sequential order would interleave a,b,a,b)
    a_positions = [i for i, (s, _) in enumerate(order) if s == "a"]
    assert a_positions[2] < len(order) - 2


def test_staged_executor_propagates_errors_in_order():
    def boom(x):
        if x == 2:
            raise ValueError("stage failed on 2")
        return x

    with StagedExecutor([Stage("id", boom), Stage("id2", lambda x: x)]) as ex:
        it = ex.map(range(4))
        assert next(it) == 0
        assert next(it) == 1
        with pytest.raises(ValueError, match="stage failed on 2"):
            list(it)

    with pytest.raises(ValueError, match="at least one stage"):
        StagedExecutor([])


def test_stream_search_latency_counters_and_queue_depth():
    p, n, k = 64, 300, 4
    db_bits = synthetic_binary_codes(n, p, seed=15)
    db = pack_bits(db_bits)
    qs = pack_bits(synthetic_queries(db_bits, 12, seed=16))
    eng = make_engine("amih", db, p)
    steps = list(stream_search(eng, [qs[:4], qs[4:8], qs[8:]], k))
    assert [sr.step for sr in steps] == [0, 1, 2]
    assert [sr.stats.queue_depth for sr in steps] == [8, 4, 0]
    for sr in steps:
        assert sr.latency_ms > 0
        assert {"p50", "p99", "mean", "count"} <= set(sr.stats.latency_ms)
    assert steps[-1].stats.latency_ms["count"] == 12


# ------------------------------------------------------------ ticket API
def test_ticket_is_int_compatible():
    t = Ticket(7)
    assert int(t) == 7 and t == 7 and hash(t) == hash(7)
    d = {7: "x"}
    assert d[t] == "x"
    assert t != Ticket(8)
    t.future.set_result(("ids", "sims"))
    assert t.result(timeout=1) == ("ids", "sims")
    assert "done" in repr(t)
