"""Training loop, checkpointing, fault tolerance, serving engine,
retrieval service — the runtime integration tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.configs import get_tiny
from repro.data import DataConfig, TokenPipeline
from repro.models import Model
from repro.optim import OptimConfig
from repro.serve import (
    RetrievalConfig,
    RetrievalService,
    ServeConfig,
    ServeEngine,
)
from repro.train import (
    StragglerWatchdog,
    TrainConfig,
    Trainer,
    TrainerConfig,
)

CFG = get_tiny("llama3_8b").replace(compute_dtype="float32")
OCFG = OptimConfig(peak_lr=1e-3, warmup_steps=5, decay_steps=40)
DCFG = DataConfig(vocab_size=CFG.vocab_size, seq_len=64, global_batch=8)


# ------------------------------------------------------------- data pipeline
def test_pipeline_deterministic_and_sharded():
    full = TokenPipeline(DCFG).global_batch_at(3)["tokens"]
    parts = []
    for s in range(4):
        pl = TokenPipeline(DCFG, shard_id=s, num_shards=4, start_step=3)
        parts.append(pl.next_batch()["tokens"])
    assert np.array_equal(np.concatenate(parts, axis=0), full)


def test_pipeline_checkpoint_resume_bit_exact():
    p1 = TokenPipeline(DCFG)
    for _ in range(5):
        p1.next_batch()
    state = p1.state_dict()
    want = p1.next_batch()["tokens"]
    p2 = TokenPipeline(DCFG)
    p2.load_state_dict(state)
    got = p2.next_batch()["tokens"]
    assert np.array_equal(got, want)


def test_pipeline_has_learnable_structure():
    toks = TokenPipeline(DCFG).global_batch_at(0)["tokens"]
    assert toks.min() >= 0 and toks.max() < DCFG.vocab_size
    # Zipfian skew: the most common token should be much more frequent
    counts = np.bincount(toks.reshape(-1), minlength=DCFG.vocab_size)
    assert counts.max() > 3 * np.median(counts[counts > 0])


# -------------------------------------------------------------- checkpointer
def test_checkpoint_roundtrip_and_atomicity():
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16)},
    }
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, tree, {"note": "x"})
        assert latest_step(d) == 7
        got, meta = restore(d, tree)
        assert meta["note"] == "x"
        np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(tree["a"]))
        assert got["b"]["c"].dtype == np.dtype(jnp.bfloat16)
        # a stale tmp dir must never be visible as a checkpoint
        os.makedirs(os.path.join(d, "step_00000009.tmp.123"))
        assert latest_step(d) == 7


def test_checkpointer_async_and_retention():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2, async_save=True)
        for s in (1, 2, 3, 4):
            ck.save(s, {"x": jnp.full((4,), s)})
        ck.wait()
        steps = sorted(
            int(n[5:]) for n in os.listdir(d) if n.startswith("step_")
        )
        assert steps == [3, 4]
        got, _ = ck.restore({"x": jnp.zeros((4,))})
        assert np.all(np.asarray(got["x"]) == 4)


def test_restore_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save(d, 0, {"x": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            restore(d, {"x": jnp.zeros((5,))})


# ------------------------------------------------------------------ trainer
def test_trainer_loss_falls_and_restart_bit_exact():
    with tempfile.TemporaryDirectory() as d:
        kw = dict(
            cfg=CFG, ocfg=OCFG, tcfg=TrainConfig(microbatches=2),
            data_cfg=DCFG,
        )
        tr = Trainer(
            rcfg=TrainerConfig(
                total_steps=14, checkpoint_every=7, checkpoint_dir=d,
                async_checkpoint=False,
            ),
            **kw,
        )
        out = tr.run()
        assert out["losses"][-1] < out["losses"][0]

        # continue 14 -> 20 in a new trainer == one uninterrupted 20-run
        tr2 = Trainer(
            rcfg=TrainerConfig(
                total_steps=20, checkpoint_every=7, checkpoint_dir=d,
                async_checkpoint=False,
            ),
            **kw,
        )
        out2 = tr2.run()

    with tempfile.TemporaryDirectory() as d2:
        tr_ref = Trainer(
            rcfg=TrainerConfig(
                total_steps=20, checkpoint_every=7, checkpoint_dir=d2,
                async_checkpoint=False,
            ),
            **kw,
        )
        ref = tr_ref.run()
    # the resumed run's tail must match the uninterrupted run bit-exactly
    np.testing.assert_array_equal(
        np.asarray(out2["losses"]), np.asarray(ref["losses"][14:])
    )


def test_trainer_crash_recovery():
    with tempfile.TemporaryDirectory() as d:
        boom = {"armed": True}

        def inject(step):
            if step == 9 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("host died")

        tr = Trainer(
            cfg=CFG, ocfg=OCFG, tcfg=TrainConfig(),
            rcfg=TrainerConfig(
                total_steps=12, checkpoint_every=4, checkpoint_dir=d,
                async_checkpoint=False,
            ),
            data_cfg=DCFG,
            failure_injector=inject,
        )
        out = tr.run()
        assert out["final_step"] == 12
        assert out["restarts"] == 1


def test_trainer_gives_up_after_max_restarts():
    with tempfile.TemporaryDirectory() as d:
        def always_fail(step):
            raise RuntimeError("permanently broken")

        tr = Trainer(
            cfg=CFG, ocfg=OCFG, tcfg=TrainConfig(),
            rcfg=TrainerConfig(
                total_steps=5, checkpoint_dir=d, max_restarts=2,
                async_checkpoint=False,
            ),
            data_cfg=DCFG,
            failure_injector=always_fail,
        )
        with pytest.raises(RuntimeError):
            tr.run()
        assert tr.restarts == 3


# ----------------------------------------------------------------- watchdog
def test_watchdog_flags_stragglers():
    events = []
    wd = StragglerWatchdog(window=20, threshold=2.0, warmup=2,
                           on_flag=events.append)
    for i in range(20):
        wd.observe(i, 0.10)
    assert not events
    assert wd.observe(20, 0.35)      # 3.5x median
    assert events and events[0].ratio == pytest.approx(3.5, rel=0.01)
    # healthy steps afterwards don't flag
    assert not wd.observe(21, 0.11)
    # consecutive slow steps escalate
    wd2 = StragglerWatchdog(window=20, warmup=2, escalate_after=2)
    for i in range(10):
        wd2.observe(i, 0.1)
    wd2.observe(10, 0.5)
    wd2.observe(11, 0.5)
    assert wd2.should_escalate


# ------------------------------------------------------------------- serving
def test_engine_greedy_matches_sequential_reference(rng):
    model = Model(CFG)
    params = model.init_params(jax.random.key(0))
    prompt = rng.integers(1, CFG.vocab_size, 10).astype(np.int32)
    eng = ServeEngine(CFG, params, ServeConfig(max_batch=3, max_seq=64,
                                               max_new_tokens=6))
    rid = eng.submit(prompt)
    out = eng.run_until_drained()[rid]

    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]})
    tmpl = model.init_cache(1, 64)
    cache = jax.tree.map(
        lambda c, t: jnp.pad(c, [(0, ts - cs) for cs, ts in zip(c.shape, t.shape)]),
        cache, tmpl,
    )
    ref = [int(np.argmax(np.asarray(logits)[0]))]
    pos = len(prompt)
    for _ in range(5):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[ref[-1]]], jnp.int32), jnp.int32(pos)
        )
        ref.append(int(np.argmax(np.asarray(lg)[0])))
        pos += 1
    assert out == ref


def test_engine_continuous_batching(rng):
    model = Model(CFG)
    params = model.init_params(jax.random.key(0))
    eng = ServeEngine(CFG, params, ServeConfig(max_batch=2, max_seq=64,
                                               max_new_tokens=4))
    rids = [
        eng.submit(rng.integers(1, CFG.vocab_size, int(rng.integers(3, 9))))
        for _ in range(5)
    ]
    res = eng.run_until_drained()
    assert sorted(res) == sorted(rids)
    assert all(len(v) == 4 for v in res.values())
    assert eng.stats["prefills"] == 5


# ----------------------------------------------------------------- retrieval
def test_retrieval_service_exact_and_sublinear(rng):
    cfg = get_tiny("gemma_2b").replace(compute_dtype="float32")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    svc = RetrievalService(
        cfg, params, RetrievalConfig(code_bits=32, aqbc_iters=5, m_tables=4)
    )
    docs = rng.integers(1, cfg.vocab_size, (150, 24)).astype(np.int32)
    info = svc.build_index(docs)
    assert info["n_docs"] == 150
    for qi in (3, 77):
        ids, sims, stats = svc.search(docs[qi], k=5)
        ids_l, sims_l = svc.search_linear(docs[qi], k=5)
        np.testing.assert_allclose(sims, sims_l, atol=1e-9)
        assert stats.probes < 150  # sublinear probing on self-queries
        # the query IS a corpus doc, so its code exists in the db:
        # the top similarity must be exactly 1.0 (ties may outrank the id)
        assert sims[0] == pytest.approx(1.0)


def test_retrieval_service_streaming_and_thread_safe_submit(rng):
    """submit is thread-safe (concurrent submitters, unique qids, no
    lost queries); run_queued(stream=True) yields per-step results as
    they complete, resolves every ticket's future, and stamps
    queue-depth + p50/p99 latency counters on each step's stats."""
    import threading

    cfg = get_tiny("gemma_2b").replace(compute_dtype="float32")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    svc = RetrievalService(
        cfg, params,
        RetrievalConfig(code_bits=32, aqbc_iters=5, m_tables=4,
                        search_batch_size=4),
    )
    docs = rng.integers(1, cfg.vocab_size, (60, 24)).astype(np.int32)
    svc.build_index(docs)

    tickets, t_lock = [], threading.Lock()

    def submitter(lo):
        for qi in range(lo, lo + 5):
            t = svc.submit(docs[qi])
            with t_lock:
                tickets.append(t)

    threads = [threading.Thread(target=submitter, args=(lo,))
               for lo in (0, 5, 10)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert svc.queue_depth() == 15
    assert sorted(int(t) for t in tickets) == list(range(15))

    steps = list(svc.run_queued(k=3, stream=True))
    assert svc.queue_depth() == 0
    assert [s.step for s in steps] == [0, 1, 2, 3]          # 15 / 4 -> 4
    assert [s.stats.queue_depth for s in steps] == [11, 7, 3, 0]
    for s in steps:
        assert {"p50", "p99"} <= set(s.stats.latency_ms)
    # every ticket resolved, results match the direct batched search
    for t in tickets:
        ids, sims = t.result(timeout=5)
        qi = int(t)   # submission order == docs order per thread slice
        assert ids.shape == (3,) and sims.shape == (3,)
    # the non-streaming API still returns the qid-keyed dict and accepts
    # tickets as keys
    t2 = svc.submit(docs[0])
    out = svc.run_queued(k=3)
    assert set(out) == {int(t2)}
    ids_d, sims_d = out[t2]
    ids_b, sims_b, _ = svc.search_batch(docs[0][None, :], k=3)
    np.testing.assert_array_equal(ids_d, ids_b[0])
    np.testing.assert_array_equal(sims_d, sims_b[0])


def test_retrieval_service_failed_drain_fails_tickets_and_requeues(rng):
    """A drain that raises mid-stream re-queues the unanswered queries
    AND fails their tickets' current futures (waiters must observe the
    dead drain, not hang); a successful retry resolves the replacement
    futures."""
    cfg = get_tiny("gemma_2b").replace(compute_dtype="float32")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    svc = RetrievalService(
        cfg, params,
        RetrievalConfig(code_bits=32, aqbc_iters=5, m_tables=4,
                        search_batch_size=2),
    )
    docs = rng.integers(1, cfg.vocab_size, (40, 24)).astype(np.int32)
    svc.build_index(docs)
    tickets = [svc.submit(docs[qi]) for qi in range(4)]

    real_knn = svc.engine.knn_batch
    calls = {"n": 0}

    def flaky(q, k):
        calls["n"] += 1
        if calls["n"] == 2:            # second batch step dies
            raise RuntimeError("device fell over")
        return real_knn(q, k)

    svc.engine.knn_batch = flaky
    # a waiter holding the PRE-failure future (e.g. blocked in result())
    # must observe the dead drain, not hang
    pre_futures = [t.future for t in tickets]
    with pytest.raises(RuntimeError, match="device fell over"):
        for _ in svc.run_queued(k=3, stream=True):
            pass
    # step 0 answered; step 1's queries re-queued with FAILED futures
    # (replaced by fresh ones that the retry drain resolves)
    assert pre_futures[0].done() and pre_futures[1].done()
    assert svc.queue_depth() == 2
    for f in pre_futures[2:]:
        with pytest.raises(RuntimeError, match="device fell over"):
            f.result(timeout=1)
    # retry drain answers the re-queued queries via replacement futures
    svc.engine.knn_batch = real_knn
    out = svc.run_queued(k=3)
    assert set(out) == {2, 3}
    for t in tickets[2:]:
        ids, sims = t.result(timeout=5)
        assert ids.shape == (3,)

    # abandoning the stream early is NOT a failure: queries re-queue
    # with their futures left pending and the next drain resolves them
    t5, t6, t7 = (svc.submit(docs[qi]) for qi in (5, 6, 7))
    for step in svc.run_queued(k=3, stream=True):
        break                              # consumer walks away
    assert svc.queue_depth() == 1          # step 0 answered t5+t6 only
    assert t5.future.done() and not t7.future.done()
    svc.run_queued(k=3)
    assert t7.result(timeout=5)[0].shape == (3,)


def test_retrieval_service_pipelined_backend_exact(rng):
    """RetrievalConfig(pipelined=True) turns on the engine-level overlap
    and still answers exactly."""
    cfg = get_tiny("gemma_2b").replace(compute_dtype="float32")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    svc = RetrievalService(
        cfg, params,
        RetrievalConfig(code_bits=32, aqbc_iters=5, m_tables=4,
                        pipelined=True),
    )
    docs = rng.integers(1, cfg.vocab_size, (80, 24)).astype(np.int32)
    svc.build_index(docs)
    assert svc.engine.overlap_verify
    for qi in (3, 41):
        ids, sims, _ = svc.search(docs[qi], k=5)
        _, sims_l = svc.search_linear(docs[qi], k=5)
        np.testing.assert_allclose(sims, sims_l, atol=1e-9)


def test_retrieval_service_sharded_backend(rng):
    """RetrievalConfig.backend="sharded_amih" + num_shards threads the
    sharded subsystem through serving; results match the linear scan."""
    cfg = get_tiny("gemma_2b").replace(compute_dtype="float32")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    svc = RetrievalService(
        cfg, params,
        RetrievalConfig(code_bits=32, aqbc_iters=5, m_tables=2,
                        backend="sharded_amih", num_shards=4),
    )
    docs = rng.integers(1, cfg.vocab_size, (90, 24)).astype(np.int32)
    svc.build_index(docs)
    assert svc.engine.plan.num_shards == 4
    ids, sims, stats = svc.search_batch(docs[:6], k=5)
    for row, qi in enumerate(range(6)):
        _, sims_l = svc.search_linear(docs[qi], k=5)
        np.testing.assert_array_equal(sims[row], sims_l)
    assert stats.backend == "sharded_amih" and stats.shards == 4
    # the old field name stays readable on the frozen config
    assert svc.rcfg.engine == "sharded_amih"
