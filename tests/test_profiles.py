"""Optimized-profile features: TP head padding, profile overrides."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_tiny
from repro.configs.profiles import optimized_opt_rules, optimized_overrides
from repro.models import Model


def test_head_padding_rounds_up_and_respects_gqa():
    cfg = get_config("llava_next_34b").replace(pad_heads_to_multiple=16)
    assert cfg.n_heads == 56            # published count untouched
    assert cfg.n_heads_padded == 64     # 56 -> 64, divisible by kv=8
    cfg2 = get_config("llama3_8b").replace(pad_heads_to_multiple=16)
    assert cfg2.n_heads_padded == 32    # already divisible: unchanged
    assert get_config("llama3_8b").n_heads_padded == 32  # pad off


def test_padded_model_runs_and_params_padded(rng):
    cfg = get_tiny("llava_next_34b").replace(
        compute_dtype="float32", n_heads=3, n_kv_heads=1,
        pad_heads_to_multiple=4,
    )
    assert cfg.n_heads_padded == 4
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    # stacked layers: (L, d, hq_padded, dh)
    assert params["layers"]["attn"]["wq"].shape[2] == 4
    batch = {
        "tokens": jnp.asarray(
            rng.integers(1, cfg.vocab_size, (2, 16)), jnp.int32
        ),
        "vision_embeds": 0.01 * jnp.ones(
            (2, cfg.vision_tokens, cfg.d_model), jnp.float32
        ),
    }
    logits, _ = model.forward(params, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_optimized_overrides_are_valid_config_fields(arch):
    over = optimized_overrides(arch)
    cfg = get_config(arch).replace(**over)  # raises on unknown fields
    assert cfg.n_heads_padded % 1 == 0
    if cfg.vocab_size >= 100_000:
        assert cfg.ce_chunk > 0


def test_optimized_opt_rules_shape():
    rules = optimized_opt_rules()
    assert rules["embed"] == ("data",)
    assert rules["experts"] == "model"  # base rules preserved


def test_optimized_tiny_configs_still_train(rng):
    """The profile knobs must not break the training path (ce_chunk +
    padding + chunks exercised together on a reduced config)."""
    over = optimized_overrides("llava_next_34b")
    cfg = get_tiny("llava_next_34b").replace(
        compute_dtype="float32",
        pad_heads_to_multiple=over.get("pad_heads_to_multiple", 0),
        ce_chunk=16,
    )
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = {
        "tokens": jnp.asarray(
            rng.integers(1, cfg.vocab_size, (2, 24)), jnp.int32
        ),
        "vision_embeds": 0.01 * jnp.ones(
            (2, cfg.vision_tokens, cfg.d_model), jnp.float32
        ),
    }
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
